//! Compress→serve round-trip property tests: a synthetic dense graph
//! compressed at several sparsity / n_q / design points must (a) encode
//! bit-identically across 1/2/4/8 encode threads, (b) decode losslessly
//! on every plane (decoded bits == the quantizer's bits on every care
//! position), and (c) serve bit-identically to the materialized dense
//! reference under both decode modes at several decode thread counts.

use sqnn_xor::compress::{
    compress_model, CompressOptions, CompressSpec, LayerSelect, LayerSpec,
};
use sqnn_xor::coordinator::{DecodeMode, EngineOptions, SqnnEngine};
use sqnn_xor::io::sqnn_file::{EntropyMode, Layer, SqnnModel};
use sqnn_xor::kernels::KernelChoice;
use sqnn_xor::models::synthetic_dense_graph;
use sqnn_xor::quant::QuantMethod;
use sqnn_xor::rng::Rng;

fn inputs(n: usize, dim: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| (0..dim).map(|_| rng.next_gaussian() as f32 * 0.5).collect())
        .collect()
}

#[test]
fn compress_serve_roundtrip_across_design_points_and_threads() {
    let dense = synthetic_dense_graph(0xAB, 32, &[24, 16], 4);
    let xs = inputs(6, 32, 99);
    for (sparsity, quant, n_in, n_out) in [
        (0.9, QuantMethod::Multibit { n_q: 1, iters: 3 }, 12usize, 0usize),
        (0.8, QuantMethod::Multibit { n_q: 2, iters: 2 }, 10, 40),
        (0.7, QuantMethod::Ternary, 8, 24),
    ] {
        let spec = CompressSpec {
            default: LayerSpec { sparsity, quant, n_in, n_out, ..Default::default() },
            ..Default::default()
        };
        // (a) The sharded encode is bit-identical: same container bytes at
        // every encode thread count.
        let mut containers = Vec::new();
        for threads in [1usize, 2, 4, 8] {
            let (m, report) = compress_model(
                &dense,
                &spec,
                &CompressOptions { encode_threads: threads, verify: true },
            )
            .unwrap();
            assert_eq!(report.layers.len(), 3, "every dense layer compressed");
            containers.push(m.to_bytes());
        }
        for (i, c) in containers.iter().enumerate().skip(1) {
            assert_eq!(
                c, &containers[0],
                "container diverged at encode threads index {i} (S={sparsity})"
            );
        }
        let compressed = SqnnModel::from_bytes(&containers[0]).unwrap();
        compressed.validate().unwrap();

        // (b) Lossless on every plane: the pipeline is deterministic, so
        // recomputing prune+quant from the dense layer gives the original
        // bit-planes; the decoded planes must match them on every care bit.
        for (li, layer) in compressed.layers.iter().enumerate() {
            let Layer::Encrypted(e) = layer else {
                panic!("layer {li} should be encrypted");
            };
            let Layer::Dense(d) = &dense.layers[li] else {
                unreachable!("source graph is all-dense");
            };
            let mask = spec.default.prune.mask_for(&d.w, d.rows, d.cols, sparsity);
            assert_eq!(mask.to_bools(), e.mask.to_bools(), "layer {li} mask drifted");
            let q = quant.quantize(&d.w, &mask);
            assert_eq!(q.alphas, e.alphas, "layer {li} alphas drifted");
            let decoded = e.decode_planes();
            assert_eq!(decoded.len(), q.planes.len());
            for (qi, (dec, orig)) in decoded.iter().zip(&q.planes).enumerate() {
                assert!(
                    orig.matches(dec),
                    "layer {li} plane {qi} is not lossless (S={sparsity})"
                );
            }
        }

        // (c) Serving the compressed chain equals serving the materialized
        // dense reference, bitwise, for both decode modes at several
        // decode thread counts (Auto kernels: eager dense cache vs fused
        // tile-streaming).
        let reference = SqnnEngine::load_native(
            compressed.to_dense_reference(),
            &[8],
            EngineOptions::default(),
        )
        .unwrap()
        .infer(&xs)
        .unwrap();
        for mode in [DecodeMode::Eager, DecodeMode::PerBatch] {
            for threads in [1usize, 2, 4, 8] {
                let got = SqnnEngine::load_native(
                    compressed.clone(),
                    &[8],
                    EngineOptions {
                        decode_threads: threads,
                        decode_mode: mode,
                        ..Default::default()
                    },
                )
                .unwrap()
                .infer(&xs)
                .unwrap();
                assert_eq!(
                    got, reference,
                    "serve diverged: S={sparsity} mode={mode:?} decode_threads={threads}"
                );
            }
        }
    }
}

#[test]
fn partial_selection_serves_mixed_chain_bit_identically() {
    // Encrypt only fc1 and fc3; fc2 passes through dense — the mixed
    // chain must still serve exactly like its dense reference.
    let dense = synthetic_dense_graph(0x51, 20, &[16, 12], 3);
    let spec = CompressSpec {
        default: LayerSpec {
            sparsity: 0.85,
            n_in: 10,
            n_out: 32,
            ..Default::default()
        },
        overrides: vec![(
            "fc3".to_string(),
            LayerSpec {
                sparsity: 0.5,
                quant: QuantMethod::Multibit { n_q: 2, iters: 1 },
                n_in: 8,
                n_out: 16,
                ..Default::default()
            },
        )],
        encrypt: LayerSelect::Named(vec!["fc1".into(), "fc3".into()]),
    };
    let (compressed, report) = compress_model(
        &dense,
        &spec,
        &CompressOptions { encode_threads: 3, verify: true },
    )
    .unwrap();
    assert_eq!(compressed.encrypted_layers().count(), 2);
    assert_eq!(report.passthrough, vec!["fc2".to_string()]);
    assert!(matches!(compressed.layers[1], Layer::Dense(_)));
    // The fc3 override took effect.
    let (_, fc3) = compressed.encrypted_layers().nth(1).unwrap();
    assert_eq!(fc3.planes.len(), 2);
    assert_eq!(fc3.planes[0].n_in, 8);

    let xs = inputs(5, 20, 7);
    let reference = SqnnEngine::load_native(
        compressed.to_dense_reference(),
        &[4],
        EngineOptions::default(),
    )
    .unwrap()
    .infer(&xs)
    .unwrap();
    for mode in [DecodeMode::Eager, DecodeMode::PerBatch] {
        let got = SqnnEngine::load_native(
            compressed.clone(),
            &[4],
            EngineOptions { decode_threads: 2, decode_mode: mode, ..Default::default() },
        )
        .unwrap()
        .infer(&xs)
        .unwrap();
        assert_eq!(got, reference, "mixed chain diverged under {mode:?}");
    }
}

#[test]
fn compressed_container_roundtrips_and_reports_consistently() {
    let dense = synthetic_dense_graph(0xC4, 16, &[12], 2);
    let spec = CompressSpec {
        default: LayerSpec { sparsity: 0.8, n_in: 10, n_out: 25, ..Default::default() },
        ..Default::default()
    };
    let (compressed, report) =
        compress_model(&dense, &spec, &CompressOptions { encode_threads: 2, verify: true })
            .unwrap();
    // Container round-trip preserves the compressed chain exactly.
    let back = SqnnModel::from_bytes(&compressed.to_bytes()).unwrap();
    assert_eq!(back.to_bytes(), compressed.to_bytes());
    // Report totals agree with the model's own Eq. 2 accounting.
    let agg = report.aggregate();
    let model_stats = compressed.quant_stats();
    assert_eq!(agg.total_bits, model_stats.total_bits);
    assert_eq!(agg.original_bits, model_stats.original_bits);
    assert_eq!(agg.total_patches, model_stats.total_patches);
    assert!(report.total_encode_secs() >= 0.0);
    let rendered = report.render();
    assert!(rendered.contains("fc1") && rendered.contains("TOTAL"), "{rendered}");
}

/// The `sqnn recode` migration path as a property: for a v2 image on
/// disk, parse → re-encode under every `--entropy` mode → reload must
/// (a) pass the command's lossless gate (the reloaded model's canonical
/// v2 image equals the original's), and (b) serve bit-identically to
/// the original across all five kernels and both decode modes. Recode
/// is packaging, never semantics.
#[test]
fn recode_v2_serves_bit_identically_across_kernels() {
    let dense = synthetic_dense_graph(0x2EC, 40, &[32, 20], 5);
    let spec = CompressSpec {
        default: LayerSpec { sparsity: 0.85, n_in: 10, n_out: 30, ..Default::default() },
        ..Default::default()
    };
    let (compressed, _) =
        compress_model(&dense, &spec, &CompressOptions { encode_threads: 2, verify: true })
            .unwrap();

    let dir = std::env::temp_dir();
    let src = dir.join(format!("sqnn-recode-src-{}.sqnn", std::process::id()));
    compressed.save_with(&src, EntropyMode::Off).unwrap();
    let src_bytes = std::fs::read(&src).unwrap();
    assert_eq!(sqnn_xor::io::sqnn_file::container_version(&src_bytes), Some(2));

    let xs = inputs(6, 40, 55);
    let original = SqnnModel::load(&src).unwrap();
    for (mode, expect_version) in [
        (EntropyMode::On, Some(3)),
        (EntropyMode::Off, Some(2)),
        (EntropyMode::Auto, None), // picks the smaller image; version varies
    ] {
        // The exact pipeline `sqnn recode` runs: read, parse, re-encode,
        // gate on losslessness, write.
        let out_bytes = original.to_bytes_with(mode);
        let reloaded = SqnnModel::from_bytes(&out_bytes).unwrap();
        assert_eq!(
            reloaded.to_bytes(),
            original.to_bytes(),
            "recode --entropy {mode:?} failed the lossless gate"
        );
        if let Some(v) = expect_version {
            assert_eq!(
                sqnn_xor::io::sqnn_file::container_version(&out_bytes),
                Some(v),
                "recode --entropy {mode:?} wrote the wrong container version"
            );
        }

        for kernel in [
            KernelChoice::Auto,
            KernelChoice::Dense,
            KernelChoice::Csr,
            KernelChoice::Fused,
            KernelChoice::Bitplane,
        ] {
            for decode_mode in [DecodeMode::Eager, DecodeMode::PerBatch] {
                let opts = EngineOptions {
                    decode_threads: 2,
                    decode_mode,
                    kernel,
                };
                let want = SqnnEngine::load_native(original.clone(), &[8], opts)
                    .unwrap()
                    .infer(&xs)
                    .unwrap();
                let got = SqnnEngine::load_native(reloaded.clone(), &[8], opts)
                    .unwrap()
                    .infer(&xs)
                    .unwrap();
                assert_eq!(
                    got, want,
                    "recoded model diverged: entropy={mode:?} kernel={kernel:?} \
                     mode={decode_mode:?}"
                );
            }
        }
    }
    let _ = std::fs::remove_file(&src);
}

#[test]
fn entropy_v3_container_is_byte_stable_lossless_and_auto_never_larger() {
    let dense = synthetic_dense_graph(0xE3, 48, &[40, 24], 6);
    let spec = CompressSpec {
        default: LayerSpec { sparsity: 0.9, n_in: 12, n_out: 0, ..Default::default() },
        ..Default::default()
    };
    let (compressed, report) =
        compress_model(&dense, &spec, &CompressOptions { encode_threads: 2, verify: true })
            .unwrap();

    let v2 = compressed.to_bytes_with(EntropyMode::Off);
    let v3 = compressed.to_bytes_with(EntropyMode::On);
    assert_eq!(v2, compressed.to_bytes(), "Off must be the raw v2 image");

    // v3 round-trip is byte-stable: decode → re-encode reproduces the
    // image bit for bit (every section parse is exact-size, every coded
    // block re-codes identically under the deterministic context models).
    let back = SqnnModel::from_bytes(&v3).unwrap();
    back.validate().unwrap();
    assert_eq!(back.to_v3_bytes(), v3, "v3 re-encode is not byte-stable");
    // v2 → v3 re-encode is lossless: the v3 image decodes to exactly the
    // model the raw v2 image holds.
    assert_eq!(back.to_bytes(), v2, "v3 decode lost information vs raw v2");

    // Auto picks the smaller image, so it is never larger than raw v2.
    let auto = compressed.to_bytes_with(EntropyMode::Auto);
    assert!(auto.len() <= v2.len(), "auto ({}) larger than v2 ({})", auto.len(), v2.len());
    assert_eq!(auto, if v3.len() < v2.len() { v3.clone() } else { v2.clone() });

    // The report's container columns account for the same images the
    // writer emits (per-layer sums over the encrypted chain).
    assert!(report.total_v2_bytes() > 0);
    assert!(report.total_v3_bytes() > 0);
    assert!(report.v3_bits_per_weight() <= report.v2_bits_per_weight());

    // The v3-decoded model serves bit-identically to its raw-v2 twin
    // across all five kernels × both decode modes × thread counts.
    let raw_twin = SqnnModel::from_bytes(&v2).unwrap();
    let xs = inputs(6, 48, 33);
    for kernel in [
        KernelChoice::Auto,
        KernelChoice::Dense,
        KernelChoice::Csr,
        KernelChoice::Fused,
        KernelChoice::Bitplane,
    ] {
        for mode in [DecodeMode::Eager, DecodeMode::PerBatch] {
            for threads in [1usize, 2, 4, 8] {
                let opts = EngineOptions { decode_threads: threads, decode_mode: mode, kernel };
                let reference = SqnnEngine::load_native(raw_twin.clone(), &[8], opts)
                    .unwrap()
                    .infer(&xs)
                    .unwrap();
                let got = SqnnEngine::load_native(back.clone(), &[8], opts)
                    .unwrap()
                    .infer(&xs)
                    .unwrap();
                assert_eq!(
                    got, reference,
                    "v3 twin diverged: kernel={kernel:?} mode={mode:?} threads={threads}"
                );
            }
        }
    }
}
