//! Property tests for the model registry's serving guarantees:
//!
//! * **Eviction is lossless** — load → evict → reload serves logits
//!   bit-identical to a fresh engine, at every kernel × decode-mode
//!   combination (eviction only drops derived state: decode plans,
//!   eager weight caches, kernel plans — never information).
//! * **The LRU bound holds** — concurrent inference across more models
//!   than `max_loaded` never observes more than `max_loaded` loaded.
//! * **Unload drains** — every request admitted before `unload` has its
//!   reply by the time `unload` returns; nothing is dropped on the
//!   floor with the engine.
//! * **Per-model policies act independently** — two models with
//!   different adaptive p99 targets, served side by side under mixed
//!   load, converge to *different* batch sizes.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use sqnn_xor::coordinator::{
    AdaptiveConfig, BatchPolicy, DecodeMode, EngineOptions, KernelChoice, ModelRegistry,
    RegistryConfig, SqnnEngine,
};
use sqnn_xor::io::sqnn_file::SqnnModel;
use sqnn_xor::models::{synthetic_layer_graph, SynthEncrypted};

const INPUT_DIM: usize = 12;
const NUM_CLASSES: usize = 4;
const BUCKETS: [usize; 2] = [1, 4];

fn model(seed: u64) -> SqnnModel {
    synthetic_layer_graph(
        seed,
        INPUT_DIM,
        &[
            SynthEncrypted { out_dim: 10, ..Default::default() },
            SynthEncrypted { out_dim: 8, nq: 2, ..Default::default() },
        ],
        &[],
        NUM_CLASSES,
    )
}

fn opts(kernel: KernelChoice, decode_mode: DecodeMode) -> EngineOptions {
    EngineOptions { decode_threads: 1, decode_mode, kernel }
}

fn registry(max_loaded: usize, engine: EngineOptions) -> ModelRegistry {
    ModelRegistry::new(RegistryConfig {
        max_loaded,
        buckets: BUCKETS.to_vec(),
        engine,
        ..Default::default()
    })
}

/// Fresh-engine oracle: one-shot logits outside any registry.
fn fresh_logits(seed: u64, engine: EngineOptions, input: &[f32]) -> Vec<f32> {
    let e = SqnnEngine::load_native(model(seed), &BUCKETS, engine).unwrap();
    e.infer(&[input.to_vec()]).unwrap().remove(0)
}

#[test]
fn evict_reload_bit_identical_across_kernels_and_decode_modes() {
    let kernels = [
        KernelChoice::Auto,
        KernelChoice::Dense,
        KernelChoice::Csr,
        KernelChoice::Fused,
        KernelChoice::Bitplane,
    ];
    let modes = [DecodeMode::Eager, DecodeMode::PerBatch];
    let inputs: Vec<Vec<f32>> =
        (0..6).map(|i| vec![0.1 + 0.02 * i as f32; INPUT_DIM]).collect();
    for kernel in kernels {
        for mode in modes {
            let engine = opts(kernel, mode);
            let ctx = format!("kernel {kernel:?} mode {mode:?}");
            let oracle: Vec<Vec<f32>> =
                inputs.iter().map(|x| fresh_logits(0xAA, engine, x)).collect();

            // max_loaded = 1: loading the second model must evict the
            // first.
            let reg = registry(1, engine);
            reg.register_model("a", model(0xAA)).unwrap();
            reg.register_model("b", model(0xBB)).unwrap();

            let first: Vec<Vec<f32>> = inputs
                .iter()
                .map(|x| reg.infer(Some("a"), x.clone()).unwrap())
                .collect();
            assert_eq!(first, oracle, "[{ctx}] registry-served != fresh engine");

            reg.infer(Some("b"), inputs[0].clone()).unwrap();
            assert!(!reg.is_loaded("a"), "[{ctx}] LRU eviction did not happen");
            assert!(reg.is_loaded("b"), "[{ctx}]");

            // Reload (evicting b in turn) and demand bit-identity.
            let again: Vec<Vec<f32>> = inputs
                .iter()
                .map(|x| reg.infer(Some("a"), x.clone()).unwrap())
                .collect();
            assert_eq!(again, oracle, "[{ctx}] evict→reload changed logits");
            assert_eq!(
                reg.loaded_names().len(),
                1,
                "[{ctx}] LRU bound violated after reload"
            );
        }
    }
}

#[test]
fn lru_bound_holds_under_concurrent_inference() {
    const MODELS: usize = 4;
    const MAX_LOADED: usize = 2;
    const THREADS: usize = 4;
    const REQS: usize = 24;

    let reg = Arc::new(registry(MAX_LOADED, opts(KernelChoice::Auto, DecodeMode::Eager)));
    let names: Vec<String> = (0..MODELS).map(|i| format!("m{i}")).collect();
    for (i, name) in names.iter().enumerate() {
        reg.register_model(name, model(0x100 + i as u64)).unwrap();
    }
    // Oracle per model, one shared probe input.
    let input = vec![0.25f32; INPUT_DIM];
    let eager = opts(KernelChoice::Auto, DecodeMode::Eager);
    let oracle: Vec<Vec<f32>> =
        (0..MODELS).map(|i| fresh_logits(0x100 + i as u64, eager, &input)).collect();

    let done = Arc::new(AtomicBool::new(false));
    let sampler = {
        let reg = reg.clone();
        let done = done.clone();
        std::thread::spawn(move || {
            let mut max_seen = 0;
            while !done.load(Ordering::SeqCst) {
                max_seen = max_seen.max(reg.loaded_names().len());
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
            max_seen
        })
    };

    let mut handles = Vec::new();
    for t in 0..THREADS {
        let reg = reg.clone();
        let names = names.clone();
        let oracle = oracle.clone();
        let input = input.clone();
        handles.push(std::thread::spawn(move || {
            for i in 0..REQS {
                let m = (t + i) % MODELS;
                let got = reg.infer(Some(names[m].as_str()), input.clone()).unwrap();
                assert_eq!(
                    got, oracle[m],
                    "thread {t} req {i}: model {m} served foreign logits mid-churn"
                );
            }
        }));
    }
    for h in handles {
        h.join().expect("churn thread panicked");
    }
    done.store(true, Ordering::SeqCst);
    let max_seen = sampler.join().unwrap();
    assert!(
        max_seen <= MAX_LOADED,
        "observed {max_seen} loaded models, bound is {MAX_LOADED}"
    );
    assert!(reg.loaded_names().len() <= MAX_LOADED);
}

#[test]
fn unload_of_in_use_model_drains_admitted_requests() {
    const IN_FLIGHT: usize = 48;
    let engine = opts(KernelChoice::Auto, DecodeMode::Eager);
    let reg = registry(4, engine);
    reg.register_model("m", model(0x77)).unwrap();
    reg.load("m").unwrap();

    let input = vec![0.4f32; INPUT_DIM];
    let oracle = fresh_logits(0x77, engine, &input);

    // Admit a pile of requests, then immediately unload while they are
    // (mostly) still queued.
    let rxs: Vec<_> = (0..IN_FLIGHT)
        .map(|_| reg.submit(Some("m"), input.clone()).expect("admission refused"))
        .collect();
    assert!(reg.unload("m").unwrap());
    assert!(!reg.is_loaded("m"));

    // `unload` tears the stack down through the shutdown drain and joins
    // the executor — so by the time it returns, every admitted request
    // already has its (correct) reply. try_recv, not recv: waiting here
    // would mask a dropped-on-the-floor request as a test hang.
    for (i, rx) in rxs.into_iter().enumerate() {
        let reply = rx
            .try_recv()
            .unwrap_or_else(|_| panic!("request {i} admitted before unload got no reply"));
        assert_eq!(reply.unwrap(), oracle, "request {i} answered with wrong logits");
    }

    // The model stays registered: next use reloads it from source.
    assert_eq!(reg.infer(Some("m"), input).unwrap(), oracle);
}

/// Two models behind one registry, each with its own adaptive p99
/// target, must converge to *different* operating points under the same
/// mixed load: the unattainably tight target drives its controller up
/// the bucket ladder (bigger batches amortize the per-batch decode),
/// while the generous target sees every window far under target with
/// underfilled batches and stays at the ladder floor.
#[test]
fn per_model_p99_targets_converge_to_different_batch_sizes() {
    use std::time::{Duration, Instant};

    // Short windows so the controllers step many times within the test
    // budget; both start at the ladder floor so any divergence is the
    // target's doing, not the initial point's.
    let adaptive = |target: Duration| {
        BatchPolicy::Adaptive(AdaptiveConfig {
            initial_batch: 1,
            initial_wait: Duration::from_micros(500),
            window: Duration::from_millis(20),
            window_intervals: 4,
            min_window_samples: 2,
            ..AdaptiveConfig::for_target(target)
        })
    };

    let reg = Arc::new(registry(2, opts(KernelChoice::Auto, DecodeMode::Eager)));
    reg.register_with_policy(
        "tight",
        sqnn_xor::coordinator::ModelSource::Model(model(0x11)),
        Some(adaptive(Duration::from_micros(1))),
    )
    .unwrap();
    reg.register_with_policy(
        "loose",
        sqnn_xor::coordinator::ModelSource::Model(model(0x12)),
        Some(adaptive(Duration::from_secs(5))),
    )
    .unwrap();

    let input = vec![0.3f32; INPUT_DIM];
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        // Mixed load: interleave the two models so both controllers see
        // live windows in the same wall-clock stretch.
        for _ in 0..8 {
            reg.infer(Some("tight"), input.clone()).unwrap();
            reg.infer(Some("loose"), input.clone()).unwrap();
        }
        let tight = reg.snapshot(Some("tight")).unwrap();
        let loose = reg.snapshot(Some("loose")).unwrap();
        assert!(tight.policy_adaptive && loose.policy_adaptive);
        if tight.batch_limit > loose.batch_limit {
            // Converged: the tight target climbed the ladder, the loose
            // one stayed at (or returned to) the floor.
            assert_eq!(tight.batch_limit, *BUCKETS.iter().max().unwrap());
            assert_eq!(loose.batch_limit, 1);
            return;
        }
        assert!(
            Instant::now() < deadline,
            "controllers never diverged: tight batch_limit {} vs loose {}",
            tight.batch_limit,
            loose.batch_limit
        );
    }
}
