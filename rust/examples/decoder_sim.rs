//! Decoder hardware design-space exploration (paper §5.1, Figs 11–12).
//!
//! Compresses an AlexNet-FC-like layer, extracts the real per-slice
//! `n_patch` trace, and sweeps the multi-bank patch-FIFO width against the
//! CSR row-decoder baseline — the experiment behind Fig 12, plus the Fig 1
//! DRAM traffic model. Run with `cargo run --release --example decoder_sim`.

use sqnn_xor::models::by_name;
use sqnn_xor::prune::magnitude_mask;
use sqnn_xor::rng::Rng;
use sqnn_xor::simulator::{simulate_csr_decode, simulate_xor_decode, GpuModel};
use sqnn_xor::sparse::CsrMatrix;
use sqnn_xor::xorenc::{EncryptConfig, XorEncoder};

fn main() {
    let mut rng = Rng::new(99);
    // A scaled AlexNet-FC5 stand-in (same S, nq, design point).
    let spec = by_name("AlexNet-FC5").unwrap().scaled(1_000_000);
    println!(
        "workload: {} ({}), {} weights, S={}, {}-bit",
        spec.name, spec.dataset, spec.weights, spec.sparsity, spec.n_q
    );

    // Nonuniform sparsity (the §5.2 regime that stresses the FIFO).
    let planes = spec.synthetic_planes_nonuniform(&mut rng);
    let enc = XorEncoder::new(EncryptConfig {
        n_in: spec.n_in,
        n_out: spec.n_out,
        seed: 5,
        block_slices: 0,
    });
    let ep = enc.encrypt_plane(&planes[0]);
    let npatch: Vec<usize> = ep.patches.iter().map(|p| p.len()).collect();
    let total: usize = npatch.iter().sum();
    println!(
        "encrypted: {} slices, {} patches ({:.4}/slice)",
        npatch.len(),
        total,
        total as f64 / npatch.len() as f64
    );

    // --- Fig 12: relative decode time vs n_FIFO, against CSR ---
    println!("\nFig 12 — relative execution time (1.0 = ideal):");
    let rows = 2048usize;
    let cols = spec.weights / rows;
    let w: Vec<f32> = (0..rows * cols).map(|_| rng.next_gaussian() as f32).collect();
    let mask = magnitude_mask(&w, spec.sparsity);
    let csr = CsrMatrix::from_dense(&w, rows, cols, Some(&mask));
    // Fully row-parallel (Fig 3's illustration: one decoder per row) and a
    // 64-decoder array; imbalance bites hardest at fine granularity.
    let dist = csr.row_nnz_distribution();
    let csr_rp = simulate_csr_decode(&dist, dist.len());
    let csr_64 = simulate_csr_decode(&dist, 64);
    println!("  CSR (decoder per row):      {:.3}", csr_rp.relative_time());
    println!("  CSR (64 row decoders):      {:.3}", csr_64.relative_time());
    for n_fifo in [1usize, 2, 4, 8] {
        let sim = simulate_xor_decode(&npatch, n_fifo, 256, 0);
        println!(
            "  proposed, n_FIFO={n_fifo}:         {:.3}  ({} stall cycles)",
            sim.relative_time(),
            sim.stall_cycles
        );
    }

    // --- Fig 1: DRAM traffic model, CSR vs dense vs proposed ---
    println!("\nFig 1 — modeled (2048x2048)·(2048x64) on a V100-class device:");
    let g = GpuModel::default();
    let dense = g.dense_mm(2048, 2048, 64);
    println!(
        "  dense MM:        {:7.1} us, {:6.1} GB/s, {:9.0} txns",
        dense.time_s * 1e6,
        dense.bandwidth / 1e9,
        dense.transactions
    );
    for s in [0.5, 0.7, 0.9, 0.95] {
        let w: Vec<f32> = (0..2048 * 2048).map(|_| rng.next_gaussian() as f32).collect();
        let mask = magnitude_mask(&w, s);
        let c = CsrMatrix::from_dense(&w, 2048, 2048, Some(&mask));
        let r = g.csr_spmm(&c, 64);
        println!(
            "  CSR S={s:.2}:      {:7.1} us, {:6.1} GB/s, {:9.0} txns",
            r.time_s * 1e6,
            r.bandwidth / 1e9,
            r.transactions
        );
    }
    let xorr = g.xor_mm(2048, 2048, 64, 0.28);
    println!(
        "  proposed (0.28b):{:7.1} us, {:6.1} GB/s, {:9.0} txns",
        xorr.time_s * 1e6,
        xorr.bandwidth / 1e9,
        xorr.transactions
    );
}
