//! Table 2 / Fig 10 driver: compress every model in the paper's zoo and
//! print bits/weight split into (A) index bits and (B) quantization bits,
//! against the ternary / (n_q+1)-bit baselines.
//!
//! AlexNet-scale tensors are generated at a scaled size by default (the
//! codec's per-weight statistics are size-invariant); pass `--full` for
//! the paper's exact element counts. Run with
//! `cargo run --release --example compress_models [--full]`.

use sqnn_xor::models::{PaperModel, PAPER_MODELS};
use sqnn_xor::prune::generate_factorized_mask;
use sqnn_xor::rng::Rng;
use sqnn_xor::xorenc::{BitPlane, EncryptConfig, XorEncoder};

fn compress_one(spec: &PaperModel, rng: &mut Rng) -> (f64, f64, f64) {
    let planes = spec.synthetic_planes(rng);
    let enc = XorEncoder::new(EncryptConfig {
        n_in: spec.n_in,
        n_out: spec.n_out,
        seed: 11,
        block_slices: 0,
    });
    let mut quant_bits = 0usize;
    for plane in &planes {
        let ep = enc.encrypt_plane(plane);
        debug_assert!(enc.verify_lossless(plane, &ep));
        quant_bits += ep.stats().total_bits;
    }
    let quant_bpw = quant_bits as f64 / spec.weights as f64;

    // (A) index bits via binary-index matrix factorization [22]: pick the
    // rank that reproduces the mask density (r scales with keep-rate).
    let rows = (spec.weights as f64).sqrt() as usize;
    let cols = spec.weights / rows;
    let rank = (((1.0 - spec.sparsity) * 200.0).ceil() as usize).max(4);
    let fm = generate_factorized_mask(rows, cols, rank, spec.sparsity, 13);
    let index_bpw = fm.index_bits_per_weight();

    (index_bpw, quant_bpw, spec.baseline_bits_per_weight())
}

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let mut rng = Rng::new(42);
    println!(
        "{:<14} {:>10} {:>6} {:>4} | {:>8} {:>8} {:>8} | {:>9} {:>7}",
        "model", "weights", "S", "nq", "(A)idx", "(B)quant", "total", "baseline", "gain"
    );
    for spec in PAPER_MODELS {
        let spec = if full || spec.weights <= 1_000_000 {
            *spec
        } else {
            spec.scaled(1_000_000)
        };
        let (a, b, base) = compress_one(&spec, &mut rng);
        let total = a + b;
        println!(
            "{:<14} {:>10} {:>6.2} {:>4} | {:>8.3} {:>8.3} {:>8.3} | {:>9.1} {:>6.1}x",
            spec.name,
            spec.weights,
            spec.sparsity,
            spec.n_q,
            a,
            b,
            total,
            base,
            base / total
        );
    }
    println!("\n(A) = pruning-index bits (binary-index matrix factorization [22]);");
    println!("(B) = quantized-weight bits in the proposed XOR-encrypted format;");
    println!("baseline = n_q-bit quantization + 1-bit dense pruning index (Fig 10).");
}
