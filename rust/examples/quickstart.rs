//! Quickstart: the core public API in ~40 lines.
//!
//! Encrypt a synthetic pruned+quantized bit-plane through the XOR-gate
//! network (paper §3), verify losslessness, and print the Eq. (2) bit
//! accounting. Run with `cargo run --release --example quickstart`.

use sqnn_xor::rng::Rng;
use sqnn_xor::xorenc::{BitPlane, EncryptConfig, XorEncoder};

fn main() {
    // A 100k-element bit-plane at 90% sparsity with balanced care bits —
    // the §3.3 synthetic workload.
    let mut rng = Rng::new(2026);
    let plane = BitPlane::synthetic(100_000, 0.90, &mut rng);
    println!(
        "plane: {} positions, {} care bits (S = {:.3})",
        plane.len(),
        plane.care_count(),
        plane.sparsity()
    );

    // The paper's design point: n_in=20 seeds decode to n_out=200 bits per
    // step, a 10x fixed-rate expansion.
    let cfg = EncryptConfig { n_in: 20, n_out: 200, seed: 7, block_slices: 0 };
    let encoder = XorEncoder::new(cfg);

    // Encrypt (Algorithm 1: incremental GF(2) solve, patch on conflict).
    let encrypted = encoder.encrypt_plane(&plane);
    let stats = encrypted.stats();
    println!(
        "encrypted: {} slices, {} patches (max n_patch = {})",
        encrypted.num_slices(),
        stats.total_patches,
        stats.max_npatch
    );
    println!(
        "bits: codes {} + n_patch {} + d_patch {} = {} (original {})",
        stats.code_bits,
        stats.npatch_bits,
        stats.dpatch_bits,
        stats.total_bits,
        stats.original_bits
    );
    println!(
        "compression ratio {:.2}x, memory reduction {:.3} (sparsity bound {:.3})",
        stats.ratio(),
        stats.memory_reduction(),
        plane.sparsity()
    );

    // Decrypt (XOR network + patch flips) and verify every care bit.
    let decoded = encoder.decrypt_plane(&encrypted);
    assert!(plane.matches(&decoded), "lossless property violated!");
    println!("decode check: all {} care bits reproduced exactly ✓", plane.care_count());
}
