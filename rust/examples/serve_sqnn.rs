//! End-to-end driver (the EXPERIMENTS.md §E2E run): the full system on a
//! real small workload.
//!
//! Pipeline: `make artifacts` trained an MLP on the synthetic digit task,
//! pruned FC1 to 95%, quantized it to 1 bit, and exported the bundle +
//! AOT-lowered HLO. This binary then:
//!   1. compresses the bundle with the XOR codec (Algorithm 1),
//!   2. reports bits/weight (the paper's headline metric),
//!   3. verifies bit-exact lossless decode,
//!   4. spins up the batching coordinator + TCP server over PJRT,
//!   5. fires concurrent client load and reports accuracy parity,
//!      throughput, and latency percentiles.
//!
//! Run with `cargo run --release --example serve_sqnn` (after `make
//! artifacts`).

use std::time::Instant;

use sqnn_xor::coordinator::{
    compress_bundle, read_bundle_meta, BatchPolicy, Coordinator, SqnnEngine,
};
use sqnn_xor::io::npy::read_npy;
use sqnn_xor::prune::factorize_greedy;
use sqnn_xor::runtime::Runtime;
use sqnn_xor::server::{Client, Server};

fn main() -> anyhow::Result<()> {
    let artifacts = std::env::args()
        .skip_while(|a| a != "--artifacts")
        .nth(1)
        .unwrap_or_else(|| "artifacts".to_string());
    let meta = read_bundle_meta(&artifacts)?;
    println!("== SQNN end-to-end: compress → serve → verify ==");
    println!(
        "model: MLP {}-{}-{}-{} | FC1 S={} nq={} | design point n_in={} n_out={}",
        meta.input_dim, meta.hidden1, meta.hidden2, meta.num_classes,
        meta.fc1_sparsity, meta.fc1_nq, meta.n_in, meta.n_out
    );

    // 1. Compress.
    let t = Instant::now();
    let model = compress_bundle(&artifacts)?;
    let compress_s = t.elapsed().as_secs_f64();
    let fc1 = model.first_encrypted().expect("bundle has an encrypted head");
    let st = fc1.quant_stats();
    println!("\n[1] compression (Algorithm 1 over {} slices per plane):", fc1.planes[0].num_slices());
    println!(
        "    quant payload (B): {:.3} bits/weight  (ratio {:.2}x, {} patches)",
        st.bits_per_weight(),
        st.ratio(),
        st.total_patches
    );
    // Index bits (A) via greedy binary-index factorization of the real mask.
    let fm = factorize_greedy(&fc1.mask, fc1.rows, fc1.cols, 64);
    let approx = fm.materialize();
    let stats = sqnn_xor::prune::mask_approx_stats(&fc1.mask, &approx);
    println!(
        "    index (A), rank-64 factorization: {:.3} bits/weight (recall {:.3}) vs 1.0 dense",
        fm.index_bits_per_weight(),
        stats.recall()
    );
    println!(
        "    total: {:.3} bits/weight vs ternary 2.0 ({}x smaller); encode took {:.2}s ({:.1} Mweight/s)",
        st.bits_per_weight() + fm.index_bits_per_weight(),
        (2.0 / (st.bits_per_weight() + fm.index_bits_per_weight())) as u32,
        compress_s,
        fc1.rows as f64 * fc1.cols as f64 * meta.fc1_nq as f64 / compress_s / 1e6,
    );

    // 2. Lossless check against the exported planes.
    let bits_arr = read_npy(format!("{artifacts}/weights/fc1_bits.npy"))?;
    let bits = bits_arr.as_u8()?;
    let decoded = fc1.decode_planes();
    let plane_len = fc1.rows * fc1.cols;
    let mut mismatches = 0usize;
    for q in 0..meta.fc1_nq {
        for j in 0..plane_len {
            if fc1.mask.get(j) && decoded[q].get(j) != (bits[q * plane_len + j] != 0) {
                mismatches += 1;
            }
        }
    }
    println!("\n[2] lossless decode: {mismatches} care-bit mismatches (must be 0)");
    assert_eq!(mismatches, 0);

    // 3. Serve over TCP with dynamic batching.
    let x = read_npy(format!("{artifacts}/weights/x_test.npy"))?;
    let y = read_npy(format!("{artifacts}/weights/y_test.npy"))?;
    let dim = x.shape[1];
    let xs: Vec<Vec<f32>> = x.as_f32()?.chunks(dim).map(|c| c.to_vec()).collect();
    let ys = y.as_i32()?.to_vec();

    let batch_sizes = meta.batch_sizes.clone();
    let art2 = artifacts.clone();
    let policy =
        BatchPolicy::Static { max_batch: 32, max_wait: std::time::Duration::from_millis(1) };
    let coordinator = Coordinator::spawn(policy, move || {
        let runtime = Runtime::cpu()?;
        let model = compress_bundle(&art2)?;
        SqnnEngine::load(&runtime, model, &art2, &batch_sizes)
    })?;
    let mut server = Server::start(coordinator.handle.clone(), "127.0.0.1:0")?;
    let addr = format!("127.0.0.1:{}", server.port);
    println!("\n[3] serving on {addr} (buckets {:?})", meta.batch_sizes);

    // 4. Concurrent client load: 8 clients, whole test set.
    let n_clients = 8usize;
    let t = Instant::now();
    let mut joins = Vec::new();
    for c in 0..n_clients {
        let addr = addr.clone();
        let xs = xs.clone();
        let ys = ys.clone();
        joins.push(std::thread::spawn(move || -> (usize, usize) {
            let mut client = Client::connect(&addr).expect("connect");
            let mut correct = 0usize;
            let mut total = 0usize;
            for i in (c..xs.len()).step_by(n_clients) {
                let logits = client.infer(&xs[i]).expect("infer");
                let pred = logits
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0;
                correct += usize::from(pred == ys[i] as usize);
                total += 1;
            }
            (correct, total)
        }));
    }
    let mut correct = 0usize;
    let mut total = 0usize;
    for j in joins {
        let (c, t) = j.join().unwrap();
        correct += c;
        total += t;
    }
    let wall = t.elapsed().as_secs_f64();
    let acc = correct as f64 / total as f64;
    let snap = coordinator.handle.metrics().snapshot();
    println!("\n[4] served {total} requests from {n_clients} clients in {wall:.2}s");
    println!(
        "    accuracy {acc:.4} (pipeline quantized accuracy {:.4}, Δ={:+.4})",
        meta.acc_sqnn,
        acc - meta.acc_sqnn
    );
    println!(
        "    throughput {:.0} req/s | batches {} (mean size {:.1}) | latency p50 {:.2} ms, p99 {:.2} ms",
        total as f64 / wall,
        snap.batches,
        snap.mean_batch_size,
        snap.latency_p50_ms,
        snap.latency_p99_ms
    );
    assert!(
        (acc - meta.acc_sqnn).abs() < 0.005,
        "accuracy parity violated: served {acc} vs pipeline {}",
        meta.acc_sqnn
    );
    println!("\nOK: lossless compression, exact accuracy parity, fixed-rate decode in-graph ✓");
    server.stop();
    Ok(())
}
