"""AOT export smoke tests: the HLO text must parse-ready for the Rust side."""

import os
import tempfile

from compile import config as C
from compile.aot import export_decode_graph, export_serve_graph


def test_serve_graph_exports_hlo_text():
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "serve.hlo.txt")
        n = export_serve_graph(1, path)
        assert n > 1000
        text = open(path).read()
        assert text.startswith("HloModule")
        # 11 entry parameters in the agreed order (nested computations from
        # the interpreted Pallas call also contain `parameter(`, so count
        # inputs in the entry layout instead).
        layout = text.splitlines()[0]
        entry_inputs = layout.split("->")[0]
        assert entry_inputs.count("f32[") == 11
        # batch-1 activation input present
        assert f"f32[1,{C.INPUT_DIM}]" in text


def test_decode_graph_exports_hlo_text():
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "decode.hlo.txt")
        n = export_decode_graph(path)
        assert n > 500
        text = open(path).read()
        assert text.startswith("HloModule")
        layout = text.splitlines()[0]
        assert layout.split("->")[0].count("f32[") == 2
        assert f"f32[{C.N_OUT},{C.N_IN}]" in text


def test_config_geometry_consistent():
    assert C.INPUT_DIM % C.N_OUT == 0, "fused kernel needs n_out | input_dim"
    assert C.N_SLICES * C.N_OUT >= C.FC1_PLANE_LEN
    assert C.N_SLICES == C.HIDDEN1 * (C.INPUT_DIM // C.N_OUT)
    assert C.N_IN <= 64
