"""Model graph tests: dense/compressed agreement, training step behaviour."""

import jax.numpy as jnp
import numpy as np

from compile import config as C
from compile.data import make_dataset
from compile.model import (accuracy, adam_init, cross_entropy, forward_dense,
                           forward_compressed, init_params, make_train_step)
from compile.sqnn import magnitude_mask, quantize_multibit, dequantize


def test_forward_shapes():
    params = init_params(0)
    x = jnp.zeros((5, C.INPUT_DIM), jnp.float32)
    logits = forward_dense(params, x)
    assert logits.shape == (5, C.NUM_CLASSES)


def test_train_step_reduces_loss():
    params = init_params(1)
    x, y = make_dataset(256, 42)
    step = make_train_step(1e-3)
    opt = adam_init(params)
    jx, jy = jnp.array(x), jnp.array(y)
    first = float(cross_entropy(forward_dense(params, jx), jy))
    for _ in range(30):
        params, opt, loss = step(params, opt, jx, jy)
    assert float(loss) < first * 0.7, f"{float(loss)} vs {first}"


def test_masked_training_keeps_pruned_weights_zero():
    params = init_params(2)
    mask = jnp.array(
        magnitude_mask(np.asarray(params["w1"]), 0.9).astype(np.float32))
    params = dict(params, w1=params["w1"] * mask)
    x, y = make_dataset(128, 7)
    step = make_train_step(1e-3, fc1_mask=mask)
    opt = adam_init(params)
    for _ in range(5):
        params, opt, _ = step(params, opt, jnp.array(x), jnp.array(y))
    w1 = np.asarray(params["w1"])
    assert np.all(w1[np.asarray(mask) == 0] == 0.0)


def test_frozen_fc1_untouched():
    params = init_params(3)
    w1_before = np.asarray(params["w1"]).copy()
    x, y = make_dataset(128, 8)
    step = make_train_step(1e-3, freeze_fc1=True)
    opt = adam_init(params)
    for _ in range(5):
        params, opt, _ = step(params, opt, jnp.array(x), jnp.array(y))
    np.testing.assert_array_equal(np.asarray(params["w1"]), w1_before)
    # but the rest did move
    assert not np.array_equal(np.asarray(params["w2"]),
                              np.asarray(init_params(3)["w2"]))


def test_compressed_forward_matches_dense_with_lossless_codes():
    """If decode(codes)+patch reproduces the quantized bits exactly, the
    compressed graph must equal the dense graph run on dequantized FC1 —
    the paper's end-to-end losslessness property, checked at graph level.

    Codes are all-zero; the patch plane then carries the full bit-plane
    (decode(0)=0, so patch == bits is a valid lossless encoding).
    """
    params = init_params(4)
    w1 = np.asarray(params["w1"])
    mask = magnitude_mask(w1, C.FC1_SPARSITY)
    alphas, bits = quantize_multibit(w1, mask, C.FC1_NQ)
    w1q = dequantize(alphas, bits, mask)
    dense_params = dict(params, w1=jnp.array(w1q))

    spr = C.INPUT_DIM // C.N_OUT
    l = C.HIDDEN1 * spr
    codes = np.zeros((C.FC1_NQ, l, C.N_IN), np.float32)
    patch = bits.reshape(C.FC1_NQ, l, C.N_OUT).astype(np.float32)
    m_xor = np.zeros((C.N_OUT, C.N_IN), np.float32)

    x, _ = make_dataset(8, 99)
    dense_logits = forward_dense(dense_params, jnp.array(x))
    comp_logits = forward_compressed(
        jnp.array(x), jnp.array(m_xor), jnp.array(codes), jnp.array(patch),
        jnp.array(mask.astype(np.float32)), jnp.array(alphas),
        params["b1"], params["w2"], params["b2"], params["w3"], params["b3"],
    )[0]
    np.testing.assert_allclose(np.array(dense_logits), np.array(comp_logits),
                               rtol=1e-4, atol=1e-4)


def test_accuracy_metric():
    logits = jnp.array([[10.0, 0.0], [0.0, 10.0], [10.0, 0.0]])
    labels = jnp.array([0, 1, 1])
    assert abs(float(accuracy(logits, labels)) - 2.0 / 3.0) < 1e-6
