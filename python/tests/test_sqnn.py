"""Prune/quantize build-path transforms (numpy mirrors of the Rust side)."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.sqnn import (dequantize, magnitude_mask, quantize_multibit)


def test_magnitude_mask_exact_count():
    rng = np.random.default_rng(0)
    w = rng.normal(size=(100, 80)).astype(np.float32)
    for s in [0.0, 0.5, 0.9, 0.95]:
        mask = magnitude_mask(w, s)
        assert mask.sum() == round((1 - s) * w.size)


def test_magnitude_mask_keeps_largest():
    w = np.array([[0.1, -5.0], [0.2, 3.0]], np.float32)
    mask = magnitude_mask(w, 0.5)
    assert mask[0, 1] and mask[1, 1]
    assert not mask[0, 0] and not mask[1, 0]


@settings(max_examples=10, deadline=None)
@given(n_q=st.integers(1, 3), seed=st.integers(0, 2**31), s=st.sampled_from([0.5, 0.9]))
def test_quantize_roundtrip_properties(n_q, seed, s):
    rng = np.random.default_rng(seed)
    w = (rng.normal(size=(40, 50)) * 0.05).astype(np.float32)
    mask = magnitude_mask(w, s)
    alphas, bits = quantize_multibit(w, mask, n_q, iters=4)
    assert alphas.shape == (n_q,)
    assert bits.shape == (n_q, 40, 50)
    assert set(np.unique(bits)).issubset({0, 1})
    deq = dequantize(alphas, bits, mask)
    # pruned → exactly zero
    assert np.all(deq[~mask] == 0.0)
    # unpruned → one of the 2^nq codebook values
    codebook = np.array([
        sum(alphas[i] if (m >> i) & 1 else -alphas[i] for i in range(n_q))
        for m in range(1 << n_q)
    ], dtype=np.float32)
    dist = np.abs(deq[mask][:, None] - codebook[None, :]).min(axis=1)
    assert np.all(dist < 1e-5)


def test_more_bits_reduce_error():
    rng = np.random.default_rng(5)
    w = (rng.normal(size=(60, 60)) * 0.1).astype(np.float32)
    mask = magnitude_mask(w, 0.8)
    errs = []
    for n_q in (1, 2, 3):
        alphas, bits = quantize_multibit(w, mask, n_q)
        deq = dequantize(alphas, bits, mask)
        errs.append(float(((w - deq)[mask] ** 2).mean()))
    assert errs[1] < errs[0] and errs[2] < errs[1]


def test_bit_planes_roughly_balanced():
    """§3's precondition for XOR encryption: care bits ~ Bernoulli(1/2)."""
    rng = np.random.default_rng(9)
    w = (rng.normal(size=(200, 200)) * 0.05).astype(np.float32)
    mask = magnitude_mask(w, 0.9)
    _, bits = quantize_multibit(w, mask, 1)
    frac = bits[0][mask].mean()
    assert 0.35 < frac < 0.65


def test_empty_mask_safe():
    w = np.ones((4, 4), np.float32)
    mask = np.zeros((4, 4), bool)
    alphas, bits = quantize_multibit(w, mask, 2)
    assert np.all(dequantize(alphas, bits, mask) == 0.0)
