"""Pallas kernels vs the pure-jnp oracle, swept with hypothesis."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import (decode_planes_ref, fc_forward_ref,
                                 reconstruct_weight_ref)
from compile.kernels.xor_decode import (decode_planes_pallas,
                                        fused_decode_fc_pallas)


def _mk_inputs(rng, n_q, n_in, n_out, out_dim, spr, batch, patch_p=0.02):
    in_dim = n_out * spr
    l = out_dim * spr
    codes = rng.integers(0, 2, (n_q, l, n_in)).astype(np.float32)
    m = rng.integers(0, 2, (n_out, n_in)).astype(np.float32)
    patch = (rng.random((n_q, l, n_out)) < patch_p).astype(np.float32)
    mask = (rng.random((out_dim, in_dim)) < 0.15).astype(np.float32)
    alphas = rng.uniform(0.05, 1.0, n_q).astype(np.float32)
    bias = rng.normal(size=out_dim).astype(np.float32)
    x = rng.normal(size=(batch, in_dim)).astype(np.float32)
    return x, codes, patch, m, mask, alphas, bias


@settings(max_examples=12, deadline=None)
@given(
    n_q=st.integers(1, 3),
    n_in=st.integers(4, 24),
    n_out=st.sampled_from([16, 49, 64]),
    l_blocks=st.integers(1, 4),
    seed=st.integers(0, 2**31),
)
def test_decode_planes_matches_ref(n_q, n_in, n_out, l_blocks, seed):
    rng = np.random.default_rng(seed)
    sb = 8
    l = sb * l_blocks
    codes = rng.integers(0, 2, (n_q, l, n_in)).astype(np.float32)
    m = rng.integers(0, 2, (n_out, n_in)).astype(np.float32)
    ref = decode_planes_ref(jnp.array(codes), jnp.array(m))
    out = decode_planes_pallas(jnp.array(codes), jnp.array(m), slices_per_block=sb)
    np.testing.assert_array_equal(np.array(ref), np.array(out))


def test_decode_output_is_binary():
    rng = np.random.default_rng(3)
    codes = rng.integers(0, 2, (2, 40, 20)).astype(np.float32)
    m = rng.integers(0, 2, (64, 20)).astype(np.float32)
    out = np.array(decode_planes_pallas(jnp.array(codes), jnp.array(m),
                                        slices_per_block=20))
    assert set(np.unique(out)).issubset({0.0, 1.0})


@settings(max_examples=10, deadline=None)
@given(
    n_q=st.integers(1, 2),
    spr=st.integers(1, 3),
    batch=st.sampled_from([1, 4]),
    seed=st.integers(0, 2**31),
)
def test_fused_fc_matches_ref(n_q, spr, batch, seed):
    rng = np.random.default_rng(seed)
    n_in, n_out, out_dim = 12, 32, 20
    args = _mk_inputs(rng, n_q, n_in, n_out, out_dim, spr, batch)
    x, codes, patch, m, mask, alphas, bias = [jnp.array(a) for a in args]
    ref = fc_forward_ref(x, codes, patch, m, mask, alphas, bias)
    out = fused_decode_fc_pallas(x, codes, patch, m, mask, alphas, bias,
                                 rows_per_block=10)
    np.testing.assert_allclose(np.array(ref), np.array(out), rtol=1e-5,
                               atol=1e-5)


def test_fused_fc_full_config_shape():
    """The exact FC1 geometry served in production (784→500, n_out=392)."""
    from compile import config as C

    rng = np.random.default_rng(0)
    spr = C.INPUT_DIM // C.N_OUT
    args = _mk_inputs(rng, C.FC1_NQ, C.N_IN, C.N_OUT, C.HIDDEN1, spr, 4)
    x, codes, patch, m, mask, alphas, bias = [jnp.array(a) for a in args]
    ref = fc_forward_ref(x, codes, patch, m, mask, alphas, bias)
    out = fused_decode_fc_pallas(x, codes, patch, m, mask, alphas, bias)
    assert out.shape == (4, C.HIDDEN1)
    np.testing.assert_allclose(np.array(ref), np.array(out), rtol=1e-4,
                               atol=1e-4)


def test_patch_flips_exactly_one_bit():
    """A single patch bit must flip exactly one decoded weight bit."""
    rng = np.random.default_rng(5)
    n_q, l, n_in, n_out = 1, 4, 8, 16
    codes = rng.integers(0, 2, (n_q, l, n_in)).astype(np.float32)
    m = rng.integers(0, 2, (n_out, n_in)).astype(np.float32)
    patch0 = np.zeros((n_q, l, n_out), np.float32)
    patch1 = patch0.copy()
    patch1[0, 2, 5] = 1.0
    out_dim, in_dim = 4, 16
    mask = np.ones((out_dim, in_dim), np.float32)
    alphas = np.array([1.0], np.float32)
    w0 = reconstruct_weight_ref(jnp.array(codes), jnp.array(patch0),
                                jnp.array(m), jnp.array(mask),
                                jnp.array(alphas), out_dim, in_dim)
    w1 = reconstruct_weight_ref(jnp.array(codes), jnp.array(patch1),
                                jnp.array(m), jnp.array(mask),
                                jnp.array(alphas), out_dim, in_dim)
    diff = np.abs(np.array(w0) - np.array(w1))
    assert (diff > 0).sum() == 1
    # flat position 2*16+5 = 37 → row 2, col 5
    assert diff[2, 5] == 2.0  # ±α flip = 2α


def test_mask_zeroes_pruned_positions():
    rng = np.random.default_rng(7)
    n_q, l, n_in, n_out = 1, 8, 10, 16
    out_dim, in_dim = 8, 16
    codes = rng.integers(0, 2, (n_q, l, n_in)).astype(np.float32)
    m = rng.integers(0, 2, (n_out, n_in)).astype(np.float32)
    patch = np.zeros((n_q, l, n_out), np.float32)
    mask = (rng.random((out_dim, in_dim)) < 0.2).astype(np.float32)
    alphas = np.array([0.7], np.float32)
    w = np.array(reconstruct_weight_ref(jnp.array(codes), jnp.array(patch),
                                        jnp.array(m), jnp.array(mask),
                                        jnp.array(alphas), out_dim, in_dim))
    assert np.all(w[mask == 0] == 0.0)
    assert np.allclose(np.abs(w[mask == 1]), 0.7, atol=1e-6)


def test_fused_rejects_misaligned_n_out():
    rng = np.random.default_rng(9)
    x = jnp.array(rng.normal(size=(2, 30)).astype(np.float32))  # 30 % 16 != 0
    codes = jnp.zeros((1, 4, 8), jnp.float32)
    patch = jnp.zeros((1, 4, 16), jnp.float32)
    m = jnp.zeros((16, 8), jnp.float32)
    mask = jnp.ones((2, 30), jnp.float32)
    alphas = jnp.ones((1,), jnp.float32)
    bias = jnp.zeros((2,), jnp.float32)
    with pytest.raises(AssertionError):
        fused_decode_fc_pallas(x, codes, patch, m, mask, alphas, bias)
