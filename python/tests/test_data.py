"""Synthetic dataset properties."""

import numpy as np

from compile.data import make_dataset


def test_shapes_and_ranges():
    x, y = make_dataset(64, 0)
    assert x.shape == (64, 784) and x.dtype == np.float32
    assert y.shape == (64,) and y.dtype == np.int32
    assert x.min() >= 0.0 and x.max() <= 1.0
    assert set(np.unique(y)).issubset(set(range(10)))


def test_deterministic_per_seed():
    x1, y1 = make_dataset(32, 5)
    x2, y2 = make_dataset(32, 5)
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(y1, y2)
    x3, _ = make_dataset(32, 6)
    assert not np.array_equal(x1, x3)


def test_classes_are_distinguishable():
    """Class-conditional means must differ — the task must be learnable."""
    x, y = make_dataset(800, 3)
    means = np.stack([x[y == c].mean(axis=0) for c in range(10)])
    d = np.linalg.norm(means[:, None] - means[None, :], axis=-1)
    off_diag = d[~np.eye(10, dtype=bool)]
    assert off_diag.min() > 0.5, off_diag.min()
