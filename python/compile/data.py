"""Procedural synthetic digit-blob dataset (MNIST stand-in).

The image has no network access and ships no datasets, so the end-to-end
driver trains on a *generated* 10-class image task: each class is a fixed
arrangement of Gaussian blobs on a 28×28 canvas, sampled with random
per-example jitter, amplitude noise, and pixel noise. The task is easy
enough for a small MLP to learn well yet non-trivial (classes share blob
positions), which is all the paper's losslessness claim needs — accuracy
parity between the quantized model and its XOR-compressed form is
dataset-independent.
"""

import numpy as np

SIDE = 28


def _class_prototype(cls: int, rng: np.random.Generator) -> np.ndarray:
    """Fixed blob layout per class: 3–5 blobs at class-specific positions."""
    proto_rng = np.random.default_rng(1000 + cls)
    n_blobs = 3 + proto_rng.integers(0, 3)
    centers = proto_rng.uniform(5, SIDE - 5, size=(n_blobs, 2))
    sigmas = proto_rng.uniform(1.5, 3.0, size=n_blobs)
    del rng
    return centers, sigmas


_YY, _XX = np.meshgrid(np.arange(SIDE), np.arange(SIDE), indexing="ij")


def render(centers: np.ndarray, sigmas: np.ndarray, jitter: np.ndarray,
           amps: np.ndarray) -> np.ndarray:
    img = np.zeros((SIDE, SIDE), dtype=np.float64)
    for (cy, cx), s, (jy, jx), a in zip(centers, sigmas, jitter, amps):
        img += a * np.exp(-(((_YY - cy - jy) ** 2) + ((_XX - cx - jx) ** 2))
                          / (2.0 * s * s))
    return img


def make_dataset(n: int, seed: int) -> tuple[np.ndarray, np.ndarray]:
    """Return (x, y): x float32 [n, 784] in [0,1], y int32 [n]."""
    rng = np.random.default_rng(seed)
    xs = np.empty((n, SIDE * SIDE), dtype=np.float32)
    ys = rng.integers(0, 10, size=n).astype(np.int32)
    protos = [_class_prototype(c, rng) for c in range(10)]
    for i in range(n):
        centers, sigmas = protos[ys[i]]
        jitter = rng.normal(0.0, 1.0, size=(len(sigmas), 2))
        amps = rng.uniform(0.7, 1.3, size=len(sigmas))
        img = render(centers, sigmas, jitter, amps)
        img += rng.normal(0.0, 0.05, size=img.shape)
        img = np.clip(img, 0.0, img.max() if img.max() > 0 else 1.0)
        if img.max() > 0:
            img = img / img.max()
        xs[i] = img.reshape(-1).astype(np.float32)
    return xs, ys
