"""Layer-2 JAX model: the SQNN MLP (784-500-300-10).

Two forward paths share the non-FC1 parameters:

* :func:`forward_dense` — ordinary dense MLP (training / baselines);
* :func:`forward_compressed` — FC1 is reconstructed *inside the graph* from
  its XOR-encrypted form through the fused Pallas kernel; this is the graph
  that `aot.py` lowers to HLO for the Rust coordinator.

Training utilities (cross-entropy loss, hand-rolled Adam — the image has no
optax) run at build time only.
"""

import jax
import jax.numpy as jnp

from . import config as C
from .kernels.ref import fc_forward_ref
from .kernels.xor_decode import fused_decode_fc_pallas


def init_params(seed: int) -> dict:
    k = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(k, 3)

    def dense(key, fan_in, fan_out):
        scale = jnp.sqrt(2.0 / fan_in)
        return jax.random.normal(key, (fan_out, fan_in), jnp.float32) * scale

    return {
        "w1": dense(k1, C.INPUT_DIM, C.HIDDEN1),
        "b1": jnp.zeros((C.HIDDEN1,), jnp.float32),
        "w2": dense(k2, C.HIDDEN1, C.HIDDEN2),
        "b2": jnp.zeros((C.HIDDEN2,), jnp.float32),
        "w3": dense(k3, C.HIDDEN2, C.NUM_CLASSES),
        "b3": jnp.zeros((C.NUM_CLASSES,), jnp.float32),
    }


def forward_dense(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    h = jax.nn.relu(x @ params["w1"].T + params["b1"])
    h = jax.nn.relu(h @ params["w2"].T + params["b2"])
    return h @ params["w3"].T + params["b3"]


def forward_compressed(
    x: jnp.ndarray,
    m_xor: jnp.ndarray,
    codes: jnp.ndarray,
    patch: jnp.ndarray,
    mask: jnp.ndarray,
    alphas: jnp.ndarray,
    b1: jnp.ndarray,
    w2: jnp.ndarray,
    b2: jnp.ndarray,
    w3: jnp.ndarray,
    b3: jnp.ndarray,
) -> jnp.ndarray:
    """The serving graph: compressed FC1 (fused Pallas decode-GEMM), dense
    FC2/FC3. Argument order here *is* the HLO parameter order the Rust
    runtime feeds — keep `aot.py` and `rust/src/coordinator` in sync.
    """
    h = jax.nn.relu(
        fused_decode_fc_pallas(x, codes, patch, m_xor, mask, alphas, b1)
    )
    h = jax.nn.relu(h @ w2.T + b2)
    return (h @ w3.T + b3,)


def forward_compressed_ref(
    x, m_xor, codes, patch, mask, alphas, b1, w2, b2, w3, b3
):
    """Identical math to :func:`forward_compressed`, but the decode-GEMM is
    the pure-jnp reference instead of the interpreted Pallas kernel.

    On the CPU PJRT backend the interpret-mode Pallas call lowers to an
    HLO region XLA cannot fuse well (§Perf); this variant lets XLA fuse the
    whole decode. pytest asserts the two kernels agree bit-for-bit, so the
    coordinator may serve either artifact — Pallas remains the TPU
    deployment path (compiled via Mosaic) and the CPU correctness vehicle.
    """
    h = jax.nn.relu(fc_forward_ref(x, codes, patch, m_xor, mask, alphas, b1))
    h = jax.nn.relu(h @ w2.T + b2)
    return (h @ w3.T + b3,)


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def accuracy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    return jnp.mean((jnp.argmax(logits, axis=1) == labels).astype(jnp.float32))


# ---------------------------------------------------------------- training

def adam_init(params: dict) -> dict:
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": 0}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mhat = jax.tree_util.tree_map(lambda m: m / (1 - b1**t), m)
    vhat = jax.tree_util.tree_map(lambda v: v / (1 - b2**t), v)
    new = jax.tree_util.tree_map(
        lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + eps), params, mhat, vhat
    )
    return new, {"m": m, "v": v, "t": t}


def make_train_step(lr: float, fc1_mask=None, freeze_fc1: bool = False):
    """Jitted Adam step. `fc1_mask` (0/1 [H1, IN]) keeps pruned FC1 weights
    at zero (mask applied to both weight and gradient); `freeze_fc1` zeroes
    the FC1 update entirely (used after quantization)."""

    def loss_fn(params, x, y):
        p = params
        if fc1_mask is not None:
            p = dict(p, w1=p["w1"] * fc1_mask)
        return cross_entropy(forward_dense(p, x), y)

    @jax.jit
    def step(params, opt, x, y):
        loss, grads = jax.value_and_grad(loss_fn)(params, x, y)
        if fc1_mask is not None:
            grads = dict(grads, w1=grads["w1"] * fc1_mask)
        if freeze_fc1:
            grads = dict(grads, w1=jnp.zeros_like(grads["w1"]),
                         b1=jnp.zeros_like(grads["b1"]))
        new_params, new_opt = adam_update(params, grads, opt, lr)
        if fc1_mask is not None:
            new_params = dict(new_params, w1=new_params["w1"] * fc1_mask)
        return new_params, new_opt, loss

    return step
