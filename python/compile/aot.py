"""AOT export: lower the serving graphs to HLO **text** for the Rust PJRT
runtime.

HLO text (not serialized HloModuleProto) is the interchange format: jax
≥ 0.5 emits protos with 64-bit instruction ids that the `xla` crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Artifacts:
  * ``sqnn_mlp_b{B}.hlo.txt`` — the compressed-FC1 MLP forward for each
    serving batch size. Parameter order (the contract with
    ``rust/src/coordinator``):
      x, m_xor, codes, patch, mask, alphas, b1, w2, b2, w3, b3
  * ``decode_planes.hlo.txt`` — standalone XOR decode (codes, m_xor → bits),
    used by the runtime integration tests and the decode-offload path.
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import config as C
from .kernels.xor_decode import decode_planes_pallas
from .model import forward_compressed, forward_compressed_ref


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def serve_arg_specs(batch: int):
    """Shapes of the serving graph inputs, in parameter order."""
    return (
        f32(batch, C.INPUT_DIM),                    # x
        f32(C.N_OUT, C.N_IN),                       # m_xor
        f32(C.FC1_NQ, C.N_SLICES, C.N_IN),          # codes
        f32(C.FC1_NQ, C.N_SLICES, C.N_OUT),         # patch
        f32(C.HIDDEN1, C.INPUT_DIM),                # mask
        f32(C.FC1_NQ),                              # alphas
        f32(C.HIDDEN1),                             # b1
        f32(C.HIDDEN2, C.HIDDEN1),                  # w2
        f32(C.HIDDEN2),                             # b2
        f32(C.NUM_CLASSES, C.HIDDEN2),              # w3
        f32(C.NUM_CLASSES),                         # b3
    )


def export_serve_graph(batch: int, out_path: str, variant: str = "pallas") -> int:
    fn = forward_compressed if variant == "pallas" else forward_compressed_ref
    lowered = jax.jit(fn).lower(*serve_arg_specs(batch))
    text = to_hlo_text(lowered)
    with open(out_path, "w") as f:
        f.write(text)
    return len(text)


def export_decode_graph(out_path: str) -> int:
    def fn(codes, m_xor):
        return (decode_planes_pallas(codes, m_xor),)

    lowered = jax.jit(fn).lower(
        f32(C.FC1_NQ, C.N_SLICES, C.N_IN), f32(C.N_OUT, C.N_IN)
    )
    text = to_hlo_text(lowered)
    with open(out_path, "w") as f:
        f.write(text)
    return len(text)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--skip-train", action="store_true",
                    help="only lower HLO; do not run the training pipeline")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    for b in C.BATCH_SIZES:
        path = os.path.join(args.out_dir, f"sqnn_mlp_b{b}.hlo.txt")
        n = export_serve_graph(b, path, "pallas")
        print(f"[aot] wrote {path} ({n} chars)")
        path = os.path.join(args.out_dir, f"sqnn_mlp_ref_b{b}.hlo.txt")
        n = export_serve_graph(b, path, "ref")
        print(f"[aot] wrote {path} ({n} chars)")
    path = os.path.join(args.out_dir, "decode_planes.hlo.txt")
    n = export_decode_graph(path)
    print(f"[aot] wrote {path} ({n} chars)")

    if not args.skip_train:
        from .pipeline import run

        run(args.out_dir)


if __name__ == "__main__":
    main()
