"""Build-time SQNN transforms: magnitude pruning and alternating multi-bit
quantization (Xu et al. ICLR'18 [32]) — the numpy mirrors of the Rust
``prune``/``quant`` modules (cross-checked by the integration tests: both
sides must agree on the artifacts they exchange).
"""

import numpy as np


def magnitude_mask(w: np.ndarray, sparsity: float) -> np.ndarray:
    """Keep the largest-|w| (1−sparsity) fraction. Returns bool mask."""
    flat = np.abs(w).reshape(-1)
    keep = int(round((1.0 - sparsity) * flat.size))
    if keep <= 0:
        return np.zeros(w.shape, dtype=bool)
    if keep >= flat.size:
        return np.ones(w.shape, dtype=bool)
    # threshold at the keep-th largest magnitude; break ties by index order
    order = np.argsort(-flat, kind="stable")[:keep]
    mask = np.zeros(flat.size, dtype=bool)
    mask[order] = True
    return mask.reshape(w.shape)


def quantize_multibit(w: np.ndarray, mask: np.ndarray, n_q: int,
                      iters: int = 6) -> tuple[np.ndarray, np.ndarray]:
    """Alternating multi-bit quantization on the unpruned weights.

    Returns ``(alphas [n_q], bits [n_q, *w.shape] in {0,1})`` such that
    ``w ≈ mask * Σ_i alphas[i] * (2*bits[i] − 1)``. Pruned positions get
    bit 0 (don't care — the XOR codec is free to overwrite them).
    """
    assert 1 <= n_q <= 8
    kept = w[mask].astype(np.float64)
    b = np.zeros((n_q, kept.size), dtype=np.float64)  # ±1
    alphas = np.zeros(n_q, dtype=np.float64)
    resid = kept.copy()
    for i in range(n_q):
        a = np.mean(np.abs(resid)) if kept.size else 0.0
        alphas[i] = a
        b[i] = np.where(resid >= 0, 1.0, -1.0)
        resid -= a * b[i]
    for _ in range(iters):
        if kept.size == 0:
            break
        # alpha-step: least squares
        bt = b.T  # [k, n_q]
        ata = bt.T @ bt
        atw = bt.T @ kept
        try:
            alphas = np.linalg.solve(ata, atw)
        except np.linalg.LinAlgError:
            pass
        # b-step: nearest codebook value
        codes = np.array(
            [[1.0 if (m >> i) & 1 else -1.0 for i in range(n_q)]
             for m in range(1 << n_q)])  # [2^nq, n_q]
        vals = codes @ alphas  # [2^nq]
        best = np.argmin(np.abs(kept[:, None] - vals[None, :]), axis=1)
        b = codes[best].T
    bits = np.zeros((n_q,) + w.shape, dtype=np.uint8)
    for i in range(n_q):
        plane = np.zeros(w.shape, dtype=np.uint8)
        plane[mask] = (b[i] > 0).astype(np.uint8)
        bits[i] = plane
    return alphas.astype(np.float32), bits


def dequantize(alphas: np.ndarray, bits: np.ndarray,
               mask: np.ndarray) -> np.ndarray:
    """Reconstruct ``mask * Σ alphas[i] (2 bits[i] − 1)`` as float32."""
    w = np.zeros(bits.shape[1:], dtype=np.float32)
    for i, a in enumerate(alphas):
        w += a * (2.0 * bits[i].astype(np.float32) - 1.0)
    return w * mask.astype(np.float32)
