"""Pure-jnp oracle for the Pallas kernels (correctness reference).

Everything is float32 arithmetic over {0,1}-valued arrays: the GF(2)
mat-vec ``M⊕ w^c`` becomes an ordinary matmul followed by ``mod 2`` (sums
are small integers, exact in f32), and the patch flip is another mod-2
addition — see DESIGN.md §Hardware-Adaptation.
"""

import jax.numpy as jnp


def decode_planes_ref(codes: jnp.ndarray, m_xor: jnp.ndarray) -> jnp.ndarray:
    """XOR-network decode of every slice of every bit-plane.

    codes:  [n_q, l, n_in]  {0,1} seeds (w^c)
    m_xor:  [n_out, n_in]   {0,1} generator matrix (M⊕)
    returns [n_q, l, n_out] {0,1} decoded bits (before patch correction)
    """
    prod = jnp.einsum("qli,oi->qlo", codes, m_xor)
    return jnp.mod(prod, 2.0)


def reconstruct_weight_ref(
    codes: jnp.ndarray,
    patch: jnp.ndarray,
    m_xor: jnp.ndarray,
    mask: jnp.ndarray,
    alphas: jnp.ndarray,
    out_dim: int,
    in_dim: int,
) -> jnp.ndarray:
    """Decode → patch-fix → dequantize → mask: the full weight decompression.

    patch: [n_q, l, n_out] {0,1} patch bit-planes (scattered d_patch)
    mask:  [out_dim, in_dim] {0,1} pruning mask
    alphas:[n_q] quantization coefficients
    returns [out_dim, in_dim] float32 weights
    """
    n_q = codes.shape[0]
    bits = jnp.mod(decode_planes_ref(codes, m_xor) + patch, 2.0)
    planes = bits.reshape(n_q, -1)[:, : out_dim * in_dim]
    planes = planes.reshape(n_q, out_dim, in_dim)
    w = jnp.einsum("q,qoi->oi", alphas, 2.0 * planes - 1.0)
    return w * mask


def fc_forward_ref(
    x: jnp.ndarray,
    codes: jnp.ndarray,
    patch: jnp.ndarray,
    m_xor: jnp.ndarray,
    mask: jnp.ndarray,
    alphas: jnp.ndarray,
    bias: jnp.ndarray,
) -> jnp.ndarray:
    """Compressed fully-connected layer: ``y = x · W(codes)ᵀ + b``."""
    out_dim, in_dim = mask.shape
    w = reconstruct_weight_ref(codes, patch, m_xor, mask, alphas, out_dim, in_dim)
    return x @ w.T + bias
