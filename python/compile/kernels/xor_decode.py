"""Layer-1 Pallas kernels: the decode hot path.

Hardware adaptation (DESIGN.md §7): the paper decodes with an ASIC XOR-gate
array at memory line rate. On TPU the same GF(2) mat-vec is a *matmul mod 2*
— MXU work — so the fixed decode rate the paper buys with XOR trees becomes
a dense `(slices × n_in)·(n_in × n_out)` GEMM with perfectly regular access.
The fused kernel goes further and never materializes the decoded weights in
HBM: each grid step decodes one block of weight rows into VMEM scratch,
dequantizes, masks, and immediately multiplies with the activation tile, so
HBM weight traffic stays at the *compressed* footprint (the paper's
bandwidth claim).

Kernels run with ``interpret=True``: real-TPU lowering emits Mosaic
custom-calls the CPU PJRT plugin cannot execute. Block shapes are still
chosen for VMEM budgets (see DESIGN.md §Perf).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows of the weight matrix decoded per fused-kernel grid step. 50 rows ×
# 784 cols × 4 B ≈ 157 KiB of decoded weights in VMEM — comfortably inside
# a TPU core's ~16 MiB VMEM alongside the activation tile.
DEFAULT_ROWS_PER_BLOCK = 50
# Slice blocks per decode-kernel grid step.
DEFAULT_SLICES_PER_BLOCK = 100


def _decode_kernel(codes_ref, m_ref, out_ref):
    """out = (codes @ Mᵀ) mod 2 for one [sb, n_in] block of slices."""
    prod = jnp.dot(codes_ref[0], m_ref[...].T)
    out_ref[0] = jnp.mod(prod, 2.0)


def decode_planes_pallas(
    codes: jnp.ndarray,
    m_xor: jnp.ndarray,
    slices_per_block: int = DEFAULT_SLICES_PER_BLOCK,
) -> jnp.ndarray:
    """Pallas version of :func:`ref.decode_planes_ref`.

    codes [n_q, l, n_in] → bits [n_q, l, n_out]; grid over (plane, slice
    block); the whole M⊕ (n_out × n_in, a few KB) is resident per step.
    """
    n_q, l, n_in = codes.shape
    n_out = m_xor.shape[0]
    sb = min(slices_per_block, l)
    assert l % sb == 0, f"slice count {l} not divisible by block {sb}"
    grid = (n_q, l // sb)
    return pl.pallas_call(
        _decode_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, sb, n_in), lambda q, s: (q, s, 0)),
            pl.BlockSpec((n_out, n_in), lambda q, s: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, sb, n_out), lambda q, s: (q, s, 0)),
        out_shape=jax.ShapeDtypeStruct((n_q, l, n_out), jnp.float32),
        interpret=True,
    )(codes, m_xor)


def _fused_kernel(x_ref, codes_ref, patch_ref, m_ref, mask_ref, alphas_ref,
                  bias_ref, out_ref, *, rows_per_block, in_dim, n_out):
    """One output-row block of `y = x · W(codes)ᵀ + b`.

    Decodes `rows_per_block` weight rows (= rows_per_block · in_dim/n_out
    slices) into VMEM, dequantizes and masks them, and contracts with the
    full activation tile. Decoded weights never leave VMEM.
    """
    n_q = codes_ref.shape[0]
    # Decode + patch-fix all planes for this block: [n_q, sb, n_out].
    bits = jnp.mod(
        jnp.einsum("qsi,oi->qso", codes_ref[...], m_ref[...]) + patch_ref[...],
        2.0,
    )
    # [n_q, rows, in_dim] → dequantize with alphas.
    planes = bits.reshape(n_q, rows_per_block, in_dim)
    w = jnp.einsum("q,qri->ri", alphas_ref[...], 2.0 * planes - 1.0)
    w = w * mask_ref[...]
    out_ref[...] = jnp.dot(x_ref[...], w.T) + bias_ref[...][None, :]


def fused_decode_fc_pallas(
    x: jnp.ndarray,
    codes: jnp.ndarray,
    patch: jnp.ndarray,
    m_xor: jnp.ndarray,
    mask: jnp.ndarray,
    alphas: jnp.ndarray,
    bias: jnp.ndarray,
    rows_per_block: int = DEFAULT_ROWS_PER_BLOCK,
) -> jnp.ndarray:
    """Fused decode→dequant→mask→matmul for a compressed FC layer.

    Requires ``n_out | in_dim`` (config guarantees it) so each encrypted
    slice lies inside one weight row and row blocks tile cleanly.
    """
    batch, in_dim = x.shape
    out_dim = mask.shape[0]
    n_q, l, n_in = codes.shape
    n_out = m_xor.shape[0]
    assert in_dim % n_out == 0, "n_out must divide the FC input width"
    spr = in_dim // n_out  # slices per weight row
    assert l == out_dim * spr, f"slice count {l} != {out_dim}*{spr}"
    rb = min(rows_per_block, out_dim)
    assert out_dim % rb == 0, f"out_dim {out_dim} not divisible by {rb}"
    sb = rb * spr  # code slices per block
    grid = (out_dim // rb,)
    kernel = functools.partial(
        _fused_kernel, rows_per_block=rb, in_dim=in_dim, n_out=n_out
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((batch, in_dim), lambda i: (0, 0)),
            pl.BlockSpec((n_q, sb, n_in), lambda i: (0, i, 0)),
            pl.BlockSpec((n_q, sb, n_out), lambda i: (0, i, 0)),
            pl.BlockSpec((n_out, n_in), lambda i: (0, 0)),
            pl.BlockSpec((rb, in_dim), lambda i: (i, 0)),
            pl.BlockSpec((n_q,), lambda i: (0,)),
            pl.BlockSpec((rb,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((batch, rb), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((batch, out_dim), jnp.float32),
        interpret=True,
    )(x, codes, patch, m_xor, mask, alphas, bias)
