"""Build-time SQNN pipeline: train → prune → quantize → export.

Produces ``artifacts/weights/`` (the tensor bundle the Rust coordinator
compresses and serves) and appends the measured accuracies to
``artifacts/meta.json``. Python never runs at inference time; this script
is invoked once by ``make artifacts``.

Stages (mirroring paper §4):
 1. train the dense MLP on the synthetic digit task;
 2. magnitude-prune FC1 to ``FC1_SPARSITY`` and retrain under the mask;
 3. quantize FC1 with alternating multi-bit quantization and fine-tune the
    remaining dense layers around the frozen quantized FC1;
 4. export mask / bit-planes / alphas / dense layers / eval tensors.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from . import config as C
from .data import make_dataset
from .model import (accuracy, adam_init, forward_dense, init_params,
                    make_train_step)
from .sqnn import dequantize, magnitude_mask, quantize_multibit


def _epoch_batches(x, y, batch, rng):
    idx = rng.permutation(len(x))
    for i in range(0, len(x) - batch + 1, batch):
        sel = idx[i : i + batch]
        yield x[sel], y[sel]


def _train(params, x, y, steps, lr, mask=None, freeze_fc1=False, seed=0):
    step = make_train_step(lr, fc1_mask=mask, freeze_fc1=freeze_fc1)
    opt = adam_init(params)
    rng = np.random.default_rng(seed)
    done = 0
    loss = float("nan")
    while done < steps:
        for bx, by in _epoch_batches(x, y, C.TRAIN_BATCH, rng):
            params, opt, loss = step(params, opt, jnp.array(bx), jnp.array(by))
            done += 1
            if done >= steps:
                break
    return params, float(loss)


def _eval_acc(params, x, y, mask=None):
    p = dict(params)
    if mask is not None:
        p["w1"] = p["w1"] * mask
    logits = forward_dense(p, jnp.array(x))
    return float(accuracy(logits, jnp.array(y)))


def run(out_dir: str = "../artifacts", verbose: bool = True) -> dict:
    wdir = os.path.join(out_dir, "weights")
    os.makedirs(wdir, exist_ok=True)

    log = print if verbose else (lambda *a, **k: None)
    xtr, ytr = make_dataset(C.TRAIN_EXAMPLES, C.DATA_SEED)
    xte, yte = make_dataset(C.TEST_EXAMPLES, C.DATA_SEED + 1)

    # 1. dense training
    params = init_params(7)
    params, loss = _train(params, xtr, ytr, C.TRAIN_STEPS, C.LEARNING_RATE)
    acc_dense = _eval_acc(params, xte, yte)
    log(f"[pipeline] dense: loss={loss:.4f} test_acc={acc_dense:.4f}")

    # 2. prune FC1 + retrain under mask
    w1 = np.asarray(params["w1"])
    mask = magnitude_mask(w1, C.FC1_SPARSITY)
    jmask = jnp.array(mask.astype(np.float32))
    params = dict(params, w1=params["w1"] * jmask)
    params, _ = _train(params, xtr, ytr, C.FINETUNE_STEPS, C.LEARNING_RATE / 2,
                       mask=jmask, seed=1)
    acc_pruned = _eval_acc(params, xte, yte, mask=jmask)
    log(f"[pipeline] pruned S={C.FC1_SPARSITY}: test_acc={acc_pruned:.4f}")

    # 3. quantize FC1, freeze it, fine-tune the rest
    w1 = np.asarray(params["w1"])
    alphas, bits = quantize_multibit(w1, mask, C.FC1_NQ)
    w1q = dequantize(alphas, bits, mask)
    params = dict(params, w1=jnp.array(w1q))
    params, _ = _train(params, xtr, ytr, C.FINETUNE_STEPS, C.LEARNING_RATE / 2,
                       freeze_fc1=True, seed=2)
    acc_sqnn = _eval_acc(params, xte, yte)
    log(f"[pipeline] quantized nq={C.FC1_NQ}: test_acc={acc_sqnn:.4f}")

    # 4. export
    np.save(f"{wdir}/fc1_mask.npy", mask.astype(np.uint8))
    np.save(f"{wdir}/fc1_bits.npy", bits.astype(np.uint8))  # [nq, H1, IN]
    np.save(f"{wdir}/fc1_alphas.npy", alphas.astype(np.float32))
    for name in ("b1", "w2", "b2", "w3", "b3"):
        np.save(f"{wdir}/{name}.npy", np.asarray(params[name], dtype=np.float32))
    np.save(f"{wdir}/x_test.npy", xte)
    np.save(f"{wdir}/y_test.npy", yte.astype(np.int32))
    # Reference logits on the first serving batch, for bit-exactness checks
    # against the Rust-served model.
    ref_logits = np.asarray(
        forward_dense(params, jnp.array(xte[: max(C.BATCH_SIZES)])),
        dtype=np.float32,
    )
    np.save(f"{wdir}/logits_ref.npy", ref_logits)

    meta = {
        "input_dim": C.INPUT_DIM,
        "hidden1": C.HIDDEN1,
        "hidden2": C.HIDDEN2,
        "num_classes": C.NUM_CLASSES,
        "fc1_sparsity": C.FC1_SPARSITY,
        "fc1_nq": C.FC1_NQ,
        "n_in": C.N_IN,
        "n_out": C.N_OUT,
        "n_slices": C.N_SLICES,
        "xor_seed": C.XOR_SEED,
        "batch_sizes": list(C.BATCH_SIZES),
        "acc_dense": acc_dense,
        "acc_pruned": acc_pruned,
        "acc_sqnn": acc_sqnn,
        "mask_rank": C.MASK_RANK,
    }
    with open(os.path.join(out_dir, "meta.json"), "w") as f:
        json.dump(meta, f, indent=2)
    log(f"[pipeline] exported weight bundle to {wdir}")
    return meta


if __name__ == "__main__":
    import sys

    out = sys.argv[1] if len(sys.argv) > 1 else "../artifacts"
    run(out)
