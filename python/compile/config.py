"""Build-time configuration shared by the JAX model, the AOT exporter, and
(through ``meta.json``) the Rust coordinator.

The end-to-end workload is the paper's LeNet-5-FC1 scenario scaled to a
trainable synthetic task: an MLP 784-500-300-10 whose first (dominant) FC
layer is pruned to 95% sparsity, quantized to 1 bit, and stored in the
XOR-encrypted format. ``N_OUT`` is chosen to divide the FC1 input width so
that every encrypted slice stays within one weight row — the alignment the
fused Pallas kernel tiles on — and to sit near the paper's design point
(n_in=20, n_out≈1/(1−S)·n_in; §3.3 / Fig 7).
"""

# ---- model architecture (LeNet5-FC-style MLP) ----
INPUT_DIM = 784
HIDDEN1 = 500  # FC1: 784×500 — 93% of parameters, the compressed layer
HIDDEN2 = 300
NUM_CLASSES = 10

# ---- SQNN pipeline ----
FC1_SPARSITY = 0.95  # paper Table 2, LeNet5 FC1
FC1_NQ = 1           # 1-bit quantization
MASK_RANK = 64       # binary-index factorization rank for the FC1 mask

# ---- XOR encryption design point ----
N_IN = 20
N_OUT = 392          # divides INPUT_DIM=784; n_out/n_in = 19.6 ≈ 1/(1−S)
XOR_SEED = 0x51534E4E  # "QSNN" — must match the Rust side's EncryptConfig

# FC1 plane geometry (row-major (HIDDEN1, INPUT_DIM) flatten)
FC1_PLANE_LEN = HIDDEN1 * INPUT_DIM
N_SLICES = (FC1_PLANE_LEN + N_OUT - 1) // N_OUT

# ---- serving ----
BATCH_SIZES = (1, 8, 32)

# ---- training (build-time only) ----
TRAIN_STEPS = 400
FINETUNE_STEPS = 150
LEARNING_RATE = 1e-3
TRAIN_BATCH = 128
DATA_SEED = 1234
TRAIN_EXAMPLES = 8192
TEST_EXAMPLES = 2048
